"""AOT compile path: lower every L2/L1 computation to HLO **text**.

Run once by ``make artifacts``; python never appears on the request path.

Interchange format is HLO text, NOT a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Artifacts (written to ``--out-dir``, default ``../artifacts``):

====================  =======================================================
encoder_layer_pallas  one EDPU call, Pallas-tiled (the decomposition proof)
encoder_layer_fused   identical arithmetic, plain jnp (fast serving path)
mha_stage             MHA Stage alone (Pallas)       — EDPU two-stage claim:
ffn_stage             FFN Stage alone (Pallas)         ffn(mha(x)) == layer(x)
mm_pu_large|standard|small  one PU invocation per Fig. 4 spec
mm_tile               a single MMSZ^3 AIE-core tile MM
softmax_row           PL softmax module (attention-shaped)
layernorm             PL LayerNorm module
gelu                  PL GELU module
====================  =======================================================

plus ``manifest.json`` describing every artifact's parameters (name, dtype,
shape, order) and outputs so the rust runtime can feed literals blindly.
"""

from __future__ import annotations

import argparse
import json
import math
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import mm_pu as mmk
from .kernels import plops


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def _entry(name, params, outputs, meta=None):
    return {
        "name": name,
        "file": f"{name}.hlo.txt",
        "params": [
            {"name": n, "shape": list(s), "dtype": d} for (n, s, d) in params
        ],
        "outputs": [
            {"shape": list(s), "dtype": d} for (s, d) in outputs
        ],
        "meta": meta or {},
    }


def lower_encoder(cfg: M.ModelConfig, *, kernels: bool):
    """Lower one encoder layer; params positional in PARAM_ORDER."""
    shapes = M.param_shapes(cfg)
    lp, e = cfg.padded_seq_len, cfg.embed_dim

    def fn(x_q, x_scale, *flat):
        p = dict(zip(M.PARAM_ORDER, flat))
        return M.encoder_layer(x_q, x_scale, p, cfg, kernels=kernels)

    args = [_spec((lp, e), "int8"), _spec((), "float32")]
    args += [_spec(*shapes[n]) for n in M.PARAM_ORDER]
    lowered = jax.jit(fn).lower(*args)
    params = [("x_q", (lp, e), "int8"), ("x_scale", (), "float32")]
    params += [(n,) + tuple(shapes[n]) for n in M.PARAM_ORDER]
    params = [(n, s, d) for (n, s, d) in params]
    outputs = [((lp, e), "float32"), ((lp, e), "int8"), ((), "float32")]
    return lowered, params, outputs


def lower_mha_stage(cfg: M.ModelConfig):
    shapes = M.param_shapes(cfg)
    names = ("wqkv", "sqkv", "bqkv", "wproj", "sproj", "bproj",
             "ln1_g", "ln1_b")
    lp, e = cfg.padded_seq_len, cfg.embed_dim

    def fn(x_q, x_scale, *flat):
        p = dict(zip(names, flat))
        return (M.mha_stage(x_q, x_scale, p, cfg, kernels=True),)

    args = [_spec((lp, e), "int8"), _spec((), "float32")]
    args += [_spec(*shapes[n]) for n in names]
    lowered = jax.jit(fn).lower(*args)
    params = [("x_q", (lp, e), "int8"), ("x_scale", (), "float32")]
    params += [(n,) + tuple(shapes[n]) for n in names]
    return lowered, params, [((lp, e), "float32")]


def lower_ffn_stage(cfg: M.ModelConfig):
    shapes = M.param_shapes(cfg)
    names = ("w1", "s1", "b1", "w2", "s2", "b2", "ln2_g", "ln2_b")
    lp, e = cfg.padded_seq_len, cfg.embed_dim

    def fn(h1, *flat):
        p = dict(zip(names, flat))
        return (M.ffn_stage(h1, p, cfg, kernels=True),)

    args = [_spec((lp, e), "float32")]
    args += [_spec(*shapes[n]) for n in names]
    lowered = jax.jit(fn).lower(*args)
    params = [("h1", (lp, e), "float32")]
    params += [(n,) + tuple(shapes[n]) for n in names]
    return lowered, params, [((lp, e), "float32")]


def lower_pu(spec: str, mmsz: int):
    m, n, k = mmk.pu_invocation_shape(spec, mmsz)

    def fn(a, b):
        return (mmk.mm_pu(a, b, mmsz=mmsz),)

    lowered = jax.jit(fn).lower(_spec((m, k), "int8"), _spec((k, n), "int8"))
    params = [("a", (m, k), "int8"), ("b", (k, n), "int8")]
    return lowered, params, [((m, n), "int32")], {"spec": spec, "m": m, "n": n, "k": k}


def lower_mm_tile(mmsz: int):
    def fn(a, b):
        return (mmk.mm_pu(a, b, mmsz=mmsz),)

    s = _spec((mmsz, mmsz), "int8")
    lowered = jax.jit(fn).lower(s, s)
    params = [("a", (mmsz, mmsz), "int8"), ("b", (mmsz, mmsz), "int8")]
    return lowered, params, [((mmsz, mmsz), "int32")]


def lower_plops(cfg: M.ModelConfig):
    lp, e, d = cfg.padded_seq_len, cfg.embed_dim, cfg.dff
    dh = cfg.head_dim
    sm_scale = 1.0 / math.sqrt(dh)

    sm = jax.jit(lambda x: (plops.softmax_pl(x, scale=sm_scale),)).lower(
        _spec((lp, lp), "float32"))
    ln = jax.jit(lambda x, g, b: (plops.layernorm_pl(x, g, b),)).lower(
        _spec((lp, e), "float32"), _spec((e,), "float32"), _spec((e,), "float32"))
    ge = jax.jit(lambda x: (plops.gelu_pl(x),)).lower(_spec((lp, d), "float32"))
    return {
        "softmax_row": (sm, [("x", (lp, lp), "float32")],
                        [((lp, lp), "float32")], {"scale": sm_scale}),
        "layernorm": (ln, [("x", (lp, e), "float32"), ("g", (e,), "float32"),
                           ("b", (e,), "float32")], [((lp, e), "float32")], {}),
        "gelu": (ge, [("x", (lp, d), "float32")], [((lp, d), "float32")], {}),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--mmsz", type=int, default=mmk.MMSZ_AIE)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    # BERT-Base and ViT-Base share (E, Dff, H) and the padded L (197->256),
    # so one lowered module serves both; the manifest records logical L.
    cfg = M.BERT_BASE
    manifest = {"mmsz": args.mmsz, "models": {
        "bert-base": {"heads": 12, "embed_dim": 768, "dff": 3072,
                      "seq_len": 256, "padded_seq_len": 256, "layers": 12},
        "vit-base": {"heads": 12, "embed_dim": 768, "dff": 3072,
                     "seq_len": 197, "padded_seq_len": 256, "layers": 12},
    }, "artifacts": []}

    jobs = []
    lowered, params, outs = lower_encoder(cfg, kernels=True)
    jobs.append(("encoder_layer_pallas", lowered, params, outs, {}))
    lowered, params, outs = lower_encoder(cfg, kernels=False)
    jobs.append(("encoder_layer_fused", lowered, params, outs, {}))
    lowered, params, outs = lower_mha_stage(cfg)
    jobs.append(("mha_stage", lowered, params, outs, {}))
    lowered, params, outs = lower_ffn_stage(cfg)
    jobs.append(("ffn_stage", lowered, params, outs, {}))
    for spec in mmk.PU_SPECS:
        lowered, params, outs, meta = lower_pu(spec, args.mmsz)
        jobs.append((f"mm_pu_{spec}", lowered, params, outs, meta))
    lowered, params, outs = lower_mm_tile(args.mmsz)
    jobs.append(("mm_tile", lowered, params, outs, {"mmsz": args.mmsz}))
    for name, (lowered, params, outs, meta) in lower_plops(cfg).items():
        jobs.append((name, lowered, params, outs, meta))

    for name, lowered, params, outs, meta in jobs:
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append(_entry(name, params, outs, meta))
        print(f"  wrote {path}  ({len(text)/1024:.0f} KiB)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"  wrote {mpath}")


if __name__ == "__main__":
    main()
