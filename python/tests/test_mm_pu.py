"""L1 kernel vs oracle: the AIE MM PU tile schedule must be exact.

Integer matmul admits no tolerance — any tiling/accumulation bug shows up
as a hard mismatch.  Hypothesis sweeps shapes and tile sizes.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import mm_pu as mmk
from compile.kernels import ref


def _rand_i8(rng, shape):
    return jnp.asarray(rng.integers(-127, 128, shape, dtype=np.int8))


@pytest.mark.parametrize("mmsz", [4, 8, 16])
@pytest.mark.parametrize("tiles", [(1, 1, 1), (2, 3, 4), (4, 1, 2)])
def test_mm_pu_exact(mmsz, tiles):
    rng = np.random.default_rng(mmsz * 100 + tiles[0])
    tm, tn, tk = tiles
    a = _rand_i8(rng, (tm * mmsz, tk * mmsz))
    b = _rand_i8(rng, (tk * mmsz, tn * mmsz))
    got = np.asarray(mmk.mm_pu(a, b, mmsz=mmsz))
    want = np.asarray(ref.mm_ref(a, b))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("mmsz", [4, 16])
@pytest.mark.parametrize("h", [1, 3, 12])
def test_bmm_pu_exact(mmsz, h):
    rng = np.random.default_rng(mmsz + h)
    a = _rand_i8(rng, (h, 2 * mmsz, mmsz))
    b = _rand_i8(rng, (h, mmsz, 2 * mmsz))
    got = np.asarray(mmk.bmm_pu(a, b, mmsz=mmsz))
    want = np.asarray(ref.bmm_ref(a, b))
    np.testing.assert_array_equal(got, want)


def test_mm_pu_saturating_inputs():
    """Extreme int8 values must not overflow the int32 accumulator path."""
    mmsz = 8
    a = jnp.full((mmsz, 4 * mmsz), -127, jnp.int8)
    b = jnp.full((4 * mmsz, mmsz), -127, jnp.int8)
    got = np.asarray(mmk.mm_pu(a, b, mmsz=mmsz))
    assert (got == 127 * 127 * 4 * mmsz).all()


def test_mm_pu_rejects_unaligned():
    a = jnp.zeros((10, 16), jnp.int8)
    b = jnp.zeros((16, 16), jnp.int8)
    with pytest.raises(AssertionError):
        mmk.mm_pu(a, b, mmsz=16)


def test_mm_pu_rejects_mismatched_inner():
    a = jnp.zeros((16, 16), jnp.int8)
    b = jnp.zeros((32, 16), jnp.int8)
    with pytest.raises(AssertionError):
        mmk.mm_pu(a, b, mmsz=16)


@settings(max_examples=25, deadline=None)
@given(
    mmsz=st.sampled_from([2, 4, 8]),
    tm=st.integers(1, 4),
    tn=st.integers(1, 4),
    tk=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_mm_pu_property(mmsz, tm, tn, tk, seed):
    rng = np.random.default_rng(seed)
    a = _rand_i8(rng, (tm * mmsz, tk * mmsz))
    b = _rand_i8(rng, (tk * mmsz, tn * mmsz))
    got = np.asarray(mmk.mm_pu(a, b, mmsz=mmsz))
    want = np.asarray(ref.mm_ref(a, b))
    np.testing.assert_array_equal(got, want)


def test_pu_invocation_shapes_match_paper():
    """Fig. 4: Large 256^3, Standard 128x128x256, Small 64x64x256."""
    assert mmk.pu_invocation_shape("large") == (256, 256, 256)
    assert mmk.pu_invocation_shape("standard") == (128, 128, 256)
    assert mmk.pu_invocation_shape("small") == (64, 64, 256)


def test_pu_specs_core_counts():
    """Core count of each PU = tiles_m * tiles_n * tiles_k (Fig. 4)."""
    for name, (tm, tn, tk, cores, _, _) in mmk.PU_SPECS.items():
        assert tm * tn * tk == cores, name
