"""Artifact sanity: every artifact exists, parses as HLO text, and the
manifest agrees with the model's parameter contract."""

import json
import os

import pytest

from compile import model as M
from compile.kernels import mm_pu as mmk

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts` first",
)


@pytest.fixture(scope="module")
def manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


def test_all_artifacts_present(manifest):
    names = {a["name"] for a in manifest["artifacts"]}
    expected = {
        "encoder_layer_pallas", "encoder_layer_fused", "mha_stage",
        "ffn_stage", "mm_pu_large", "mm_pu_standard", "mm_pu_small",
        "mm_tile", "softmax_row", "layernorm", "gelu",
    }
    assert expected <= names
    for a in manifest["artifacts"]:
        path = os.path.join(ART, a["file"])
        assert os.path.exists(path), a["file"]
        text = open(path).read()
        assert "ENTRY" in text and "HloModule" in text, a["file"]


def test_encoder_manifest_matches_param_order(manifest):
    art = {a["name"]: a for a in manifest["artifacts"]}
    enc = art["encoder_layer_pallas"]
    names = [p["name"] for p in enc["params"]]
    assert names[:2] == ["x_q", "x_scale"]
    assert tuple(names[2:]) == M.PARAM_ORDER
    shapes = M.param_shapes(M.BERT_BASE)
    for p in enc["params"][2:]:
        s, d = shapes[p["name"]]
        assert tuple(p["shape"]) == s
        assert p["dtype"] == d
    # fused variant has the identical signature
    assert enc["params"] == art["encoder_layer_fused"]["params"]


def test_encoder_outputs(manifest):
    art = {a["name"]: a for a in manifest["artifacts"]}
    outs = art["encoder_layer_pallas"]["outputs"]
    assert [tuple(o["shape"]) for o in outs] == [(256, 768), (256, 768), ()]
    assert [o["dtype"] for o in outs] == ["float32", "int8", "float32"]


def test_pu_artifact_shapes(manifest):
    art = {a["name"]: a for a in manifest["artifacts"]}
    for spec in ("large", "standard", "small"):
        m, n, k = mmk.pu_invocation_shape(spec)
        a = art[f"mm_pu_{spec}"]
        assert a["meta"]["m"] == m and a["meta"]["n"] == n and a["meta"]["k"] == k
        assert tuple(a["params"][0]["shape"]) == (m, k)
        assert tuple(a["params"][1]["shape"]) == (k, n)
        assert tuple(a["outputs"][0]["shape"]) == (m, n)


def test_stage_artifacts_compose(manifest):
    """mha_stage output shape == ffn_stage input shape (the EDPU chain)."""
    art = {a["name"]: a for a in manifest["artifacts"]}
    mha_out = art["mha_stage"]["outputs"][0]
    ffn_in = art["ffn_stage"]["params"][0]
    assert mha_out["shape"] == ffn_in["shape"]
    assert mha_out["dtype"] == ffn_in["dtype"] == "float32"


def test_models_metadata(manifest):
    models = manifest["models"]
    assert models["bert-base"]["seq_len"] == 256
    assert models["vit-base"]["seq_len"] == 197
    assert models["vit-base"]["padded_seq_len"] == 256
    assert manifest["mmsz"] == 64


def test_hlo_text_no_64bit_id_proto(manifest):
    """Interchange must be text (xla_extension 0.5.1 rejects jax>=0.5
    serialized protos) — i.e. files must be ASCII HLO, not binary."""
    for a in manifest["artifacts"]:
        with open(os.path.join(ART, a["file"]), "rb") as f:
            head = f.read(64)
        assert head.startswith(b"HloModule"), a["file"]
