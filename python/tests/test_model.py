"""L2 model: EDPU-tiled (Pallas) vs fused arithmetic, stage composition,
quantization error, and §IV.A workload accounting."""

import math

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile.kernels import ref

TINY = M.ModelConfig("tiny", heads=4, embed_dim=64, dff=128, seq_len=32,
                     layers=2, mmsz=16)


def _quant_input(cfg, seed=1):
    x = jax.random.normal(jax.random.PRNGKey(seed),
                          (cfg.padded_seq_len, cfg.embed_dim), jnp.float32)
    sx = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / 127.0
    return ref.quantize(x, sx), sx


@pytest.fixture(scope="module")
def tiny_setup():
    p = M.init_params(jax.random.PRNGKey(0), TINY)
    xq, sx = _quant_input(TINY)
    return p, xq, sx


def test_kernelized_equals_fused(tiny_setup):
    """The EDPU tiling must be arithmetically invisible."""
    p, xq, sx = tiny_setup
    out_k, q_k, s_k = M.encoder_layer(xq, sx, p, TINY, kernels=True)
    out_f, q_f, s_f = M.encoder_layer_fused(xq, sx, p, TINY)
    np.testing.assert_array_equal(np.asarray(q_k), np.asarray(q_f))
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_f),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(float(s_k), float(s_f), rtol=1e-6)


def test_stage_composition(tiny_setup):
    """ffn_stage(mha_stage(x)) == encoder_layer(x) — the EDPU 2-stage claim."""
    p, xq, sx = tiny_setup
    h1 = M.mha_stage(xq, sx, p, TINY, kernels=True)
    out = M.ffn_stage(h1, p, TINY, kernels=True)
    full, _, _ = M.encoder_layer(xq, sx, p, TINY, kernels=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(full),
                               rtol=1e-6, atol=1e-6)


def test_quantization_error_bounded(tiny_setup):
    """int8 path must stay close to the fp32 reference (limited accuracy
    loss — the premise for running Int8 on the AIE, §V.A)."""
    p, xq, sx = tiny_setup
    out_q, _, _ = M.encoder_layer_fused(xq, sx, p, TINY)
    fp = M.encoder_layer_fp32(ref.dequantize(xq, sx), M.dequant_params(p), TINY)
    err = float(jnp.max(jnp.abs(out_q - fp)))
    # LayerNorm output is O(1); 0.25 absolute is ~2% of the dynamic range.
    assert err < 0.25, f"quantization error too large: {err}"


def test_layer_chaining(tiny_setup):
    """Chaining via the returned (q, scale) equals re-quantizing the fp32
    output — the contract the rust runtime relies on between layers."""
    p, xq, sx = tiny_setup
    out, q, s = M.encoder_layer_fused(xq, sx, p, TINY)
    q2 = ref.quantize(out, s)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q2))
    # run a second layer from the chained tensors: must not blow up
    out2, _, _ = M.encoder_layer_fused(q, s, p, TINY)
    assert np.isfinite(np.asarray(out2)).all()


def test_padded_seq_len():
    assert M.VIT_BASE.padded_seq_len == 256  # 197 -> 256, the paper's pad
    assert M.BERT_BASE.padded_seq_len == 256
    assert TINY.padded_seq_len == 32


def test_workload_matches_design_case():
    """§V.B: one BERT-Base EDPU iteration = 4x 256x768x768, 12x QK^T,
    12x AV, and the two FFN matmuls."""
    wl = M.mm_workload(M.BERT_BASE)
    assert (4, 256, 768, 768) in wl
    assert (12, 256, 256, 64) in wl
    assert (12, 256, 64, 256) in wl
    assert (1, 256, 3072, 768) in wl
    assert (1, 256, 768, 3072) in wl


def test_mm_count_is_5h_plus_3():
    """§IV.A: computing one MHA + FFN takes 5*Head+3 matmuls; with the
    merged (independent-linear) QKV the LB count collapses to 4 but the
    ATB count stays 2*Head."""
    for cfg in (M.BERT_BASE, M.VIT_BASE, TINY):
        wl = M.mm_workload(cfg)
        n_mm = sum(c for (c, *_rest) in wl)
        assert n_mm == 2 * cfg.heads + 6


def test_total_ops_bert():
    """FFN ops = 2.416 GOP (paper Table VI cross-check: 29.83 TOPS at
    0.081 ms); MHA MM ops = 1.41 GOP."""
    ffn = 2 * (256 * 3072 * 768 + 256 * 768 * 3072)
    mha = 2 * (4 * 256 * 768 * 768 + 12 * 256 * 256 * 64 + 12 * 256 * 64 * 256)
    assert M.total_ops(M.BERT_BASE) == ffn + mha
    assert abs(ffn - 2.416e9) / 2.416e9 < 0.01
    assert abs(mha - 1.409e9) / 1.409e9 < 0.01


def test_attention_rows_sum_to_one(tiny_setup):
    """Internal consistency: MHA output must be LayerNorm-ed (unit std)."""
    p, xq, sx = tiny_setup
    h1 = np.asarray(M.mha_stage(xq, sx, p, TINY, kernels=False))
    np.testing.assert_allclose(h1.mean(-1), 0.0, atol=1e-4)


def test_head_split_merge_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(2), (32, 64), jnp.float32)
    back = M._merge_heads(M._split_heads(x, 4))
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


def test_dyn_quant_range():
    x = jnp.asarray([[-3.0, 0.0, 3.0]], jnp.float32)
    q, s = M.dyn_quant(x)
    assert np.asarray(q).max() == 127 and np.asarray(q).min() == -127
    np.testing.assert_allclose(float(s), 3.0 / 127.0, rtol=1e-6)
