"""Hypothesis sweeps over the L2 model: the EDPU tiling must be
arithmetically invisible for ANY valid (heads, dims, seq, mmsz)
combination, not just the benchmark configurations."""

import math

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.kernels import ref


def _cfg(heads, head_dim, dff_mult, seq, mmsz):
    e = heads * head_dim
    return M.ModelConfig(
        "prop", heads=heads, embed_dim=e, dff=e * dff_mult,
        seq_len=seq, layers=1, mmsz=mmsz,
    )


@settings(max_examples=8, deadline=None)
@given(
    heads=st.sampled_from([1, 2, 4]),
    head_dim=st.sampled_from([16, 32]),
    dff_mult=st.sampled_from([2, 4]),
    seq=st.integers(8, 48),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernelized_equals_fused_any_config(heads, head_dim, dff_mult, seq, seed):
    mmsz = min(16, head_dim)
    cfg = _cfg(heads, head_dim, dff_mult, seq, mmsz)
    p = M.init_params(jax.random.PRNGKey(seed % 1000), cfg)
    lp = cfg.padded_seq_len
    x = jax.random.normal(jax.random.PRNGKey(seed % 997), (lp, cfg.embed_dim), jnp.float32)
    sx = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / 127.0
    xq = ref.quantize(x, sx)
    out_k, q_k, s_k = M.encoder_layer(xq, sx, p, cfg, kernels=True)
    out_f, q_f, s_f = M.encoder_layer_fused(xq, sx, p, cfg)
    np.testing.assert_array_equal(np.asarray(q_k), np.asarray(q_f))
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_f),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    heads=st.sampled_from([2, 4, 8]),
    head_dim=st.sampled_from([16, 32, 64]),
    seq=st.integers(8, 64),
)
def test_workload_identity_5h_plus_3(heads, head_dim, seq):
    """§IV.A: 5*Head+3 matmuls per layer (per-head linear accounting)."""
    cfg = _cfg(heads, head_dim, 4, seq, 16)
    # count from the model's own workload enumeration
    wl = M.mm_workload(cfg)
    n = sum(count for (count, _m, _n, _k) in wl)
    assert n == 2 * heads + 6  # merged-QKV form of 5H+3


@settings(max_examples=10, deadline=None)
@given(seq=st.integers(1, 512), mmsz=st.sampled_from([16, 32, 64, 128]))
def test_padding_is_minimal_multiple(seq, mmsz):
    cfg = _cfg(2, mmsz, 2, seq, mmsz)
    lp = cfg.padded_seq_len
    assert lp % mmsz == 0
    assert lp >= seq
    assert lp - seq < mmsz  # minimal padding


def test_softmax_rows_of_attention_sum_to_one():
    cfg = _cfg(2, 16, 2, 24, 8)
    p = M.init_params(jax.random.PRNGKey(0), cfg)
    lp = cfg.padded_seq_len
    x = jax.random.normal(jax.random.PRNGKey(1), (lp, cfg.embed_dim), jnp.float32)
    sx = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / 127.0
    h1 = M.mha_stage(ref.quantize(x, sx), sx, p, cfg, kernels=False)
    assert np.isfinite(np.asarray(h1)).all()


@settings(max_examples=6, deadline=None)
@given(scale_exp=st.integers(-6, 2))
def test_dyn_quant_scale_invariance(scale_exp):
    """Scaling the input scales the dyn-quant scale; the int8 codes are
    identical — the EDPU int8 path is magnitude-invariant."""
    base = jnp.asarray([[0.5, -1.0, 0.25, 1.0]], jnp.float32)
    s = float(2.0 ** scale_exp)
    q1, s1 = M.dyn_quant(base)
    q2, s2 = M.dyn_quant(base * s)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    np.testing.assert_allclose(float(s2), float(s1) * s, rtol=1e-6)
