"""PL-branch operator kernels (softmax / layernorm / gelu) vs oracles."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import plops, ref


def _randf(rng, shape, lo=-4.0, hi=4.0):
    return jnp.asarray(rng.uniform(lo, hi, shape).astype(np.float32))


@pytest.mark.parametrize("rows,cols", [(8, 16), (24, 33), (64, 256), (1, 7)])
@pytest.mark.parametrize("scale", [1.0, 0.125])
def test_softmax(rows, cols, scale):
    rng = np.random.default_rng(rows * cols)
    x = _randf(rng, (rows, cols))
    got = np.asarray(plops.softmax_pl(x, scale=scale))
    want = np.asarray(ref.softmax_ref(x, scale=scale))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got.sum(-1), 1.0, rtol=1e-5)


def test_softmax_3d_batch():
    """Attention-shaped [H, L, L] input must flatten correctly."""
    rng = np.random.default_rng(7)
    x = _randf(rng, (4, 16, 16))
    got = np.asarray(plops.softmax_pl(x, scale=0.25))
    want = np.asarray(ref.softmax_ref(x, scale=0.25))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_softmax_large_logits_stable():
    """Max-subtraction must prevent overflow for large logits."""
    x = jnp.asarray([[1000.0, 1000.0, -1000.0]] * 8, jnp.float32)
    got = np.asarray(plops.softmax_pl(x))
    assert np.isfinite(got).all()
    np.testing.assert_allclose(got[:, :2], 0.5, rtol=1e-5)


@pytest.mark.parametrize("rows,cols", [(8, 16), (32, 768), (5, 12)])
def test_layernorm(rows, cols):
    rng = np.random.default_rng(rows + cols)
    x = _randf(rng, (rows, cols))
    g = _randf(rng, (cols,), 0.5, 1.5)
    b = _randf(rng, (cols,), -0.5, 0.5)
    got = np.asarray(plops.layernorm_pl(x, g, b))
    want = np.asarray(ref.layernorm_ref(x, g, b))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_layernorm_output_statistics():
    rng = np.random.default_rng(3)
    x = _randf(rng, (16, 512))
    g = jnp.ones((512,), jnp.float32)
    b = jnp.zeros((512,), jnp.float32)
    got = np.asarray(plops.layernorm_pl(x, g, b))
    np.testing.assert_allclose(got.mean(-1), 0.0, atol=1e-5)
    np.testing.assert_allclose(got.std(-1), 1.0, atol=1e-2)


@pytest.mark.parametrize("rows,cols", [(8, 16), (16, 3072), (3, 5)])
def test_gelu(rows, cols):
    rng = np.random.default_rng(rows)
    x = _randf(rng, (rows, cols), -6.0, 6.0)
    got = np.asarray(plops.gelu_pl(x))
    want = np.asarray(ref.gelu_ref(x))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_gelu_asymptotes():
    x = jnp.asarray([[-20.0, 0.0, 20.0]] * 4, jnp.float32)
    got = np.asarray(plops.gelu_pl(x))
    np.testing.assert_allclose(got[:, 0], 0.0, atol=1e-6)
    np.testing.assert_allclose(got[:, 1], 0.0, atol=1e-7)
    np.testing.assert_allclose(got[:, 2], 20.0, rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    rows=st.integers(1, 32),
    cols=st.integers(2, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_plops_property(rows, cols, seed):
    rng = np.random.default_rng(seed)
    x = _randf(rng, (rows, cols))
    np.testing.assert_allclose(
        np.asarray(plops.softmax_pl(x)),
        np.asarray(ref.softmax_ref(x)), rtol=1e-5, atol=1e-6)
    g = _randf(rng, (cols,), 0.5, 1.5)
    b = _randf(rng, (cols,), -0.5, 0.5)
    np.testing.assert_allclose(
        np.asarray(plops.layernorm_pl(x, g, b)),
        np.asarray(ref.layernorm_ref(x, g, b)), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(plops.gelu_pl(x)),
        np.asarray(ref.gelu_ref(x)), rtol=1e-5, atol=1e-6)
