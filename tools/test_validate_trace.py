#!/usr/bin/env python3
"""Unit tests for tools/validate_trace.py (stdlib only — run directly or
via pytest): python3 tools/test_validate_trace.py"""

import io
import json
import os
import sys
import tempfile
import unittest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from validate_trace import validate_doc, validate_events, validate_file  # noqa: E402


def ev(name="e", ph="i", ts=0, pid=1, tid=0, **extra):
    d = {"name": name, "ph": ph, "ts": ts, "pid": pid, "tid": tid}
    d.update(extra)
    return d


class ValidEvents(unittest.TestCase):
    def test_minimal_instant_and_span_pass(self):
        events = [
            ev("proc", ph="M", args={"name": "serve"}),
            ev("submit", ts=0),
            ev("batch", ph="X", ts=10, dur=5, tid=1),
            ev("queue", ph="C", ts=10, tid=1, args={"in_flight": 3}),
        ]
        del events[0]["ts"]  # metadata events may omit ts entirely
        self.assertEqual(validate_events(events), [])

    def test_nested_begin_end_pairs_balance(self):
        events = [
            ev("outer", ph="B", ts=0),
            ev("inner", ph="B", ts=1),
            ev("inner", ph="E", ts=2),
            ev("outer", ph="E", ts=3),
        ]
        self.assertEqual(validate_events(events), [])

    def test_cross_track_interleaving_is_fine(self):
        # tracks are independent timelines: ts may move backwards when
        # switching tracks as long as each track stays monotone
        events = [ev("a", ts=100, tid=0), ev("b", ts=5, tid=1), ev("c", ts=100, tid=0)]
        self.assertEqual(validate_events(events), [])

    def test_zero_duration_span_and_fractional_ts_pass(self):
        events = [ev("x", ph="X", ts=1.5, dur=0)]
        self.assertEqual(validate_events(events), [])

    def test_equal_timestamps_on_one_track_pass(self):
        events = [ev("a", ts=7), ev("b", ts=7)]
        self.assertEqual(validate_events(events), [])


class InvalidEvents(unittest.TestCase):
    def assert_one_error(self, events, fragment):
        errors = validate_events(events)
        self.assertEqual(len(errors), 1, errors)
        self.assertIn(fragment, errors[0])

    def test_end_without_begin_fails(self):
        self.assert_one_error([ev("x", ph="E", ts=0)], "E without a matching B")

    def test_unclosed_begin_fails(self):
        self.assert_one_error([ev("x", ph="B", ts=0)], "unclosed B span")

    def test_mismatched_end_name_fails(self):
        events = [ev("outer", ph="B", ts=0), ev("wrong", ph="E", ts=1)]
        self.assert_one_error(events, "name mismatch")

    def test_backwards_timestamp_on_one_track_fails(self):
        self.assert_one_error([ev("a", ts=10), ev("b", ts=9)], "goes backwards")

    def test_negative_span_duration_fails(self):
        self.assert_one_error([ev("x", ph="X", ts=0, dur=-1)], "negative dur")

    def test_span_without_duration_fails(self):
        self.assert_one_error([ev("x", ph="X", ts=0)], "missing/non-numeric dur")

    def test_counter_with_non_numeric_args_fails(self):
        events = [ev("q", ph="C", ts=0, args={"depth": "three"})]
        self.assert_one_error(events, "must all be numeric")

    def test_counter_without_args_fails(self):
        self.assert_one_error([ev("q", ph="C", ts=0)], "non-empty args")

    def test_missing_ts_fails_for_non_metadata(self):
        e = ev("x")
        del e["ts"]
        self.assert_one_error([e], "missing/non-numeric ts")

    def test_boolean_ts_is_not_numeric(self):
        self.assert_one_error([ev("x", ts=True)], "missing/non-numeric ts")

    def test_unsupported_phase_fails(self):
        self.assert_one_error([ev("x", ph="Z", ts=0)], "unsupported phase")

    def test_missing_name_fails(self):
        e = ev(ph="i", ts=0)
        del e["name"]
        self.assert_one_error([e], "missing/empty name")

    def test_non_integer_pid_fails(self):
        self.assert_one_error([ev("x", ts=0, pid="serve")], "pid must be an integer")


class DocumentShapes(unittest.TestCase):
    def test_object_with_trace_events_and_bare_array_both_validate(self):
        events = [ev("a", ts=0)]
        self.assertEqual(validate_doc({"traceEvents": events}), [])
        self.assertEqual(validate_doc(events), [])

    def test_object_without_trace_events_fails(self):
        self.assertTrue(validate_doc({"events": []}))

    def test_scalar_top_level_fails(self):
        self.assertTrue(validate_doc("not a trace"))


class FileEntryPoint(unittest.TestCase):
    def run_on(self, payload, as_json=True):
        with tempfile.NamedTemporaryFile(
            "w", suffix=".json", delete=False, encoding="utf-8"
        ) as f:
            f.write(json.dumps(payload) if as_json else payload)
            path = f.name
        try:
            out = io.StringIO()
            return validate_file(path, out=out), out.getvalue()
        finally:
            os.unlink(path)

    def test_valid_file_exits_zero(self):
        code, out = self.run_on({"traceEvents": [ev("a", ts=0)]})
        self.assertEqual(code, 0)
        self.assertIn("OK", out)

    def test_invalid_file_exits_one(self):
        code, out = self.run_on({"traceEvents": [ev("a", ts=10), ev("b", ts=1)]})
        self.assertEqual(code, 1)
        self.assertIn("FAIL", out)

    def test_unparseable_file_exits_two(self):
        code, _ = self.run_on("{not json", as_json=False)
        self.assertEqual(code, 2)


if __name__ == "__main__":
    unittest.main()
