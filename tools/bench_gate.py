#!/usr/bin/env python3
"""Bench-trajectory regression gate for BENCH_hotpath.json (stdlib only).

Compares the *fresh* hotpath bench run (``--current``, the JSON CI's smoke
step just wrote) against the *checked-in* trajectory baseline
(``--baseline``, the repo's BENCH_hotpath.json) and fails the job when the
perf trajectory regresses:

* the current run must carry non-empty ``rows`` (an empty run means the
  bench recorded nothing — always a failure);
* every gated ``derived`` metric must stay within the relative tolerance
  of its baseline value in its stated direction — throughput-style
  metrics are higher-is-better (``current >= baseline * (1 -
  tolerance)``), the contention-overhead ratio is lower-is-better
  (``current <= baseline * (1 + tolerance)``).  The default tolerance is
  0.5 (±50%) — wide enough for CI-runner jitter, tight enough to catch a
  real regression;
* improvements beyond the tolerance pass with a nudge to refresh the
  baseline so the trajectory stays honest;
* a gated metric missing from the *baseline* warns and passes (a newly
  added bench row predates the committed baseline — refreshing the
  baseline makes it enforcing); missing from the *current* run still
  fails (the bench stopped producing it).

Bootstrap: until the first measured trajectory point is committed the
baseline carries empty rows.  That state fails the gate too (the ROADMAP
open item), unless ``--allow-bootstrap`` is passed — CI uses it together
with the step that records and commits the first measured point, so the
gate becomes enforcing the moment a baseline exists.

Usage:
    python3 tools/bench_gate.py --current BENCH_smoke.json \
        --baseline BENCH_hotpath.json [--tolerance 0.5] [--allow-bootstrap]

Exit code 0 = gate passed, 1 = regression/empty rows, 2 = bad invocation.
"""

import argparse
import json
import sys

# Gated derived metrics, with their direction:
#   engine_speedup_mha_batch64  (higher) — exact/fast DES median ratio
#   dse_points_per_sec          (higher) — cold-cache exploration throughput
#   serve_router_reqs_per_sec   (higher) — virtual-clock routing throughput
#   serve_contention_overhead   (lower)  — contended/uncontended modeled p50
#       on the same partition (virtual clock, deterministic); growth means
#       the shared-memory contention model got more pessimistic
#   serve_failover_reqs_per_sec (higher) — routing throughput with a
#       scripted mid-stream crash + recovery (the fault-era path: orphan
#       drain, survivor re-admission, recovery rejoin)
#   serve_trace_overhead        (lower)  — traced/untraced host-time median
#       ratio on the same routing loop; growth means the observability
#       layer's cheap-when-on contract is eroding
#   serve_contention_pessimism  (lower)  — single-pass contended p50 /
#       fixed-point contended p50 on the same oversubscribed partition
#       (virtual clock, >= 1 by construction); growth means the
#       conservative single-pass bound is drifting further from the
#       calibrated fixed point and over-throttling by more
#   serve_cluster_reqs_per_sec  (higher) — routing throughput across a
#       2-board heterogeneous cluster behind shared NIC/switch pools (the
#       cluster-era admission plane: per-board ledgers, network-throttled
#       members, board-aware energy rollup)
#   serve_router_scaling        (lower)  — indexed-route 64-backend /
#       2-backend per-pass median over the same request count (pure
#       routing, no batcher); growth means per-request admission cost is
#       creeping back toward a linear rescan as the fleet widens, i.e.
#       the event-driven admission index is losing its edge
GATED_METRICS = (
    ("engine_speedup_mha_batch64", "higher"),
    ("dse_points_per_sec", "higher"),
    ("serve_router_reqs_per_sec", "higher"),
    ("serve_contention_overhead", "lower"),
    ("serve_failover_reqs_per_sec", "higher"),
    ("serve_trace_overhead", "lower"),
    ("serve_contention_pessimism", "lower"),
    ("serve_cluster_reqs_per_sec", "higher"),
    ("serve_router_scaling", "lower"),
)


def load_doc(path, role):
    # exit 2 (bad invocation), not 1 (regression) — CI wrappers tell
    # "perf regressed" apart from "gate invoked wrong"
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench gate: cannot read {role} {path!r}: {e}", file=sys.stderr)
        raise SystemExit(2)
    if not isinstance(doc, dict):
        print(f"bench gate: {role} {path!r} is not a JSON object", file=sys.stderr)
        raise SystemExit(2)
    return doc


def rows_of(doc):
    rows = doc.get("rows")
    return rows if isinstance(rows, dict) else {}


def derived_of(doc):
    # tolerate "derived": null / non-object in malformed records
    derived = doc.get("derived")
    return derived if isinstance(derived, dict) else {}


def metric(doc, name):
    v = derived_of(doc).get(name)
    return float(v) if isinstance(v, (int, float)) and not isinstance(v, bool) else None


def run_gate(current, baseline, tolerance, allow_bootstrap, out=sys.stdout):
    """Returns the exit code; prints one line per metric to ``out``."""
    failures = []
    if not rows_of(current):
        failures.append("current run has empty rows — the bench recorded nothing")
    if not rows_of(baseline):
        if allow_bootstrap:
            print(
                "bench gate: baseline has no measured rows yet (bootstrap) — "
                "gate passes vacuously; commit a measured BENCH_hotpath.json "
                "to make it enforcing",
                file=out,
            )
        else:
            failures.append(
                "baseline has empty rows — commit a measured BENCH_hotpath.json "
                "(cargo bench --bench hotpath -- --json BENCH_hotpath.json) or "
                "pass --allow-bootstrap"
            )
    else:
        cur_smoke = derived_of(current).get("smoke")
        base_smoke = derived_of(baseline).get("smoke")
        if cur_smoke != base_smoke:
            print(
                f"bench gate: warning — mode mismatch (current smoke={cur_smoke}, "
                f"baseline smoke={base_smoke}); comparison is apples-to-oranges",
                file=out,
            )
        for name, direction in GATED_METRICS:
            base = metric(baseline, name)
            cur = metric(current, name)
            if base is None:
                # a metric the baseline predates (a newly added bench row)
                # must not fail the gate against the stale baseline — it
                # becomes enforcing once the baseline is refreshed
                print(
                    f"bench gate: warning — {name}: missing from baseline "
                    "derived metrics (new metric?); refresh the committed "
                    "baseline to make it enforcing",
                    file=out,
                )
                continue
            if cur is None:
                failures.append(f"{name}: missing from current derived metrics")
                continue
            if base <= 0:
                failures.append(f"{name}: non-positive baseline value {base}")
                continue
            ratio = cur / base
            # the documented contract is symmetric around 1.0 in the
            # metric's own ratio: higher-is-better regresses below
            # (1 - tolerance), lower-is-better regresses above
            # (1 + tolerance)
            if direction == "higher":
                regressed = ratio < 1.0 - tolerance
                improved = ratio > 1.0 + tolerance
                limit = f"floor {1.0 - tolerance:.2f}x"
            else:
                regressed = ratio > 1.0 + tolerance
                improved = ratio < 1.0 - tolerance
                limit = f"ceiling {1.0 + tolerance:.2f}x"
            if regressed:
                failures.append(
                    f"{name}: regression — {cur:g} vs baseline {base:g} "
                    f"({ratio:.2f}x, {direction}-is-better, {limit})"
                )
            elif improved:
                print(
                    f"bench gate: {name}: {cur:g} vs baseline {base:g} "
                    f"({ratio:.2f}x) — improvement beyond tolerance; consider "
                    "refreshing the committed baseline",
                    file=out,
                )
            else:
                print(
                    f"bench gate: {name}: {cur:g} vs baseline {base:g} "
                    f"({ratio:.2f}x, {direction}-is-better) within ±{tolerance:.0%}",
                    file=out,
                )
    if failures:
        for f in failures:
            print(f"bench gate: FAIL — {f}", file=out)
        return 1
    print("bench gate: OK", file=out)
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--current", required=True, help="fresh bench JSON (smoke run)")
    ap.add_argument("--baseline", required=True, help="checked-in BENCH_hotpath.json")
    ap.add_argument(
        "--tolerance",
        type=float,
        default=0.5,
        help="relative tolerance on each gated metric (default 0.5 = ±50%%)",
    )
    ap.add_argument(
        "--allow-bootstrap",
        action="store_true",
        help="pass vacuously while the baseline still has empty rows",
    )
    args = ap.parse_args(argv)
    if not 0.0 < args.tolerance < 1.0:
        ap.error("--tolerance must be in (0, 1)")
    current = load_doc(args.current, "current run")
    baseline = load_doc(args.baseline, "baseline")
    return run_gate(current, baseline, args.tolerance, args.allow_bootstrap)


if __name__ == "__main__":
    sys.exit(main())
