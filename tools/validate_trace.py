#!/usr/bin/env python3
"""Well-formedness checker for Chrome trace-event JSON (stdlib only).

Validates the documents ``cat serve --trace``/``cat explore --trace``
emit — and, more generally, any trace in the subset of the Chrome
trace-event format that Perfetto's JSON importer accepts:

* top level is either ``{"traceEvents": [...]}`` or a bare event array;
* every event is an object with a ``name`` and a supported phase ``ph``
  (``B``/``E``/``X``/``i``/``I``/``C``/``M``);
* every non-metadata event carries integer ``pid``/``tid`` and a
  numeric ``ts``; metadata (``M``) events may omit ``ts``;
* per track (``pid``, ``tid``), timestamps are monotone non-decreasing
  in file order — the property that makes a trace render as a clean
  timeline rather than interleaved garbage;
* complete events (``X``) carry a numeric ``dur >= 0``;
* begin/end pairs (``B``/``E``) balance per track, with matching names;
* counter events (``C``) carry a non-empty all-numeric ``args`` object.

Usage:
    python3 tools/validate_trace.py trace.json [more.json ...]

Exit code 0 = every file valid, 1 = at least one violation, 2 = a file
could not be read or parsed at all.
"""

import json
import sys

KNOWN_PHASES = frozenset("BEXiICM")


def _is_num(v):
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def validate_events(events):
    """Return a list of violation strings (empty = well-formed)."""
    errors = []
    last_ts = {}
    open_spans = {}
    for i, ev in enumerate(events):
        where = f"event {i}"
        if not isinstance(ev, dict):
            errors.append(f"{where}: not an object")
            continue
        name = ev.get("name")
        if not isinstance(name, str) or not name:
            errors.append(f"{where}: missing/empty name")
            name = "?"
        where = f"event {i} ({name!r})"
        ph = ev.get("ph")
        if not isinstance(ph, str) or ph not in KNOWN_PHASES:
            errors.append(f"{where}: unsupported phase {ph!r}")
            continue
        pid, tid = ev.get("pid"), ev.get("tid")
        if not isinstance(pid, int) or isinstance(pid, bool):
            errors.append(f"{where}: pid must be an integer, got {pid!r}")
            continue
        if not isinstance(tid, int) or isinstance(tid, bool):
            errors.append(f"{where}: tid must be an integer, got {tid!r}")
            continue
        if ph == "M":
            continue  # metadata names tracks; no ts required
        ts = ev.get("ts")
        if not _is_num(ts):
            errors.append(f"{where}: missing/non-numeric ts {ts!r}")
            continue
        track = (pid, tid)
        prev = last_ts.get(track)
        if prev is not None and ts < prev:
            errors.append(
                f"{where}: ts {ts} goes backwards on track pid={pid} tid={tid} "
                f"(previous {prev})"
            )
        last_ts[track] = ts
        if ph == "X":
            dur = ev.get("dur")
            if not _is_num(dur):
                errors.append(f"{where}: X event missing/non-numeric dur {dur!r}")
            elif dur < 0:
                errors.append(f"{where}: negative dur {dur}")
        elif ph == "B":
            open_spans.setdefault(track, []).append(name)
        elif ph == "E":
            stack = open_spans.get(track) or []
            if not stack:
                errors.append(f"{where}: E without a matching B on pid={pid} tid={tid}")
            else:
                opened = stack.pop()
                if opened != name:
                    errors.append(
                        f"{where}: E name mismatch — closes {name!r} but "
                        f"{opened!r} is open"
                    )
        elif ph == "C":
            args = ev.get("args")
            if not isinstance(args, dict) or not args:
                errors.append(f"{where}: counter needs a non-empty args object")
            elif not all(_is_num(v) for v in args.values()):
                errors.append(f"{where}: counter args must all be numeric")
    for (pid, tid), stack in open_spans.items():
        if stack:
            errors.append(
                f"unclosed B span(s) {stack!r} on track pid={pid} tid={tid}"
            )
    return errors


def validate_doc(doc):
    """Validate a parsed document (object-with-traceEvents or bare array)."""
    if isinstance(doc, dict):
        events = doc.get("traceEvents")
        if not isinstance(events, list):
            return ["top-level object has no traceEvents array"]
    elif isinstance(doc, list):
        events = doc
    else:
        return ["top level must be an object or an array"]
    return validate_events(events)


def validate_file(path, out=sys.stdout):
    """Validate one file; returns the process exit code contribution."""
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"validate_trace: cannot read {path!r}: {e}", file=sys.stderr)
        return 2
    errors = validate_doc(doc)
    if errors:
        for e in errors:
            print(f"validate_trace: {path}: {e}", file=out)
        print(f"validate_trace: FAIL — {path}: {len(errors)} violation(s)", file=out)
        return 1
    n = len(doc.get("traceEvents", doc) if isinstance(doc, dict) else doc)
    print(f"validate_trace: OK — {path}: {n} event(s)", file=out)
    return 0


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    if not argv:
        print(__doc__, file=sys.stderr)
        return 2
    return max(validate_file(p) for p in argv)


if __name__ == "__main__":
    sys.exit(main())
