#!/usr/bin/env python3
"""Unit tests for tools/bench_gate.py (stdlib only — run directly or via
pytest): python3 tools/test_bench_gate.py"""

import io
import os
import sys
import unittest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from bench_gate import run_gate  # noqa: E402


def doc(rows=None, derived=None):
    d = {"schema": "cat-bench-v1", "bench": "hotpath", "rows": rows or {}, "derived": {}}
    if rows:
        d["rows"] = rows
    if derived is not None:
        d["derived"] = derived
    return d


def measured(
    engine=3.0,
    dse=50.0,
    serve=200000.0,
    contention=2.0,
    failover=150000.0,
    trace_overhead=1.2,
    pessimism=1.05,
    cluster=120000.0,
    router_scaling=4.0,
    smoke=True,
):
    return doc(
        rows={"engine/mha_scenario_batch64_fast": {"median_ns": 1.0, "iters": 2}},
        derived={
            "engine_speedup_mha_batch64": engine,
            "dse_points_per_sec": dse,
            "serve_router_reqs_per_sec": serve,
            "serve_contention_overhead": contention,
            "serve_failover_reqs_per_sec": failover,
            "serve_trace_overhead": trace_overhead,
            "serve_contention_pessimism": pessimism,
            "serve_cluster_reqs_per_sec": cluster,
            "serve_router_scaling": router_scaling,
            "smoke": smoke,
        },
    )


def gate(current, baseline, tolerance=0.5, allow_bootstrap=False):
    out = io.StringIO()
    code = run_gate(current, baseline, tolerance, allow_bootstrap, out=out)
    return code, out.getvalue()


class BenchGateTests(unittest.TestCase):
    def test_identical_runs_pass(self):
        code, out = gate(measured(), measured())
        self.assertEqual(code, 0, out)
        self.assertIn("OK", out)

    def test_within_tolerance_passes(self):
        code, out = gate(measured(engine=1.6), measured(engine=3.0))
        self.assertEqual(code, 0, out)  # 0.53x >= 0.5x floor

    def test_regression_fails(self):
        code, out = gate(measured(engine=1.4), measured(engine=3.0))
        self.assertEqual(code, 1)
        self.assertIn("regression", out)
        self.assertIn("engine_speedup_mha_batch64", out)

    def test_any_single_metric_regression_fails(self):
        code, out = gate(measured(serve=1000.0), measured())
        self.assertEqual(code, 1)
        self.assertIn("serve_router_reqs_per_sec", out)

    def test_improvement_beyond_tolerance_passes_with_nudge(self):
        code, out = gate(measured(dse=200.0), measured(dse=50.0))
        self.assertEqual(code, 0, out)
        self.assertIn("refreshing", out)

    def test_contention_overhead_growth_fails_lower_is_better(self):
        # overhead is a ratio (contended/uncontended p50): growth beyond
        # tolerance = the contention model regressed
        code, out = gate(measured(contention=5.0), measured(contention=2.0))
        self.assertEqual(code, 1)
        self.assertIn("serve_contention_overhead", out)
        self.assertIn("regression", out)

    def test_contention_overhead_within_tolerance_passes(self):
        code, out = gate(measured(contention=2.8), measured(contention=2.0))
        self.assertEqual(code, 0, out)  # 1.4x growth < 1.5x ceiling

    def test_contention_overhead_ceiling_is_symmetric_with_the_docs(self):
        # the contract is cur > baseline * (1 + tolerance) fails — NOT the
        # looser cur > baseline / (1 - tolerance); 1.75x growth must fail
        code, out = gate(measured(contention=3.5), measured(contention=2.0))
        self.assertEqual(code, 1, out)
        self.assertIn("serve_contention_overhead", out)
        self.assertIn("ceiling", out)

    def test_contention_overhead_drop_is_an_improvement_not_a_failure(self):
        code, out = gate(measured(contention=1.05), measured(contention=4.0))
        self.assertEqual(code, 0, out)
        self.assertIn("refreshing", out)

    def test_empty_current_rows_fail(self):
        code, out = gate(doc(derived={"smoke": True}), measured())
        self.assertEqual(code, 1)
        self.assertIn("empty rows", out)

    def test_empty_baseline_fails_without_bootstrap(self):
        code, out = gate(measured(), doc())
        self.assertEqual(code, 1)
        self.assertIn("baseline has empty rows", out)

    def test_empty_baseline_passes_with_bootstrap(self):
        code, out = gate(measured(), doc(), allow_bootstrap=True)
        self.assertEqual(code, 0, out)
        self.assertIn("bootstrap", out)

    def test_bootstrap_does_not_mask_empty_current(self):
        code, out = gate(doc(), doc(), allow_bootstrap=True)
        self.assertEqual(code, 1)
        self.assertIn("current run has empty rows", out)

    def test_missing_metric_in_current_fails(self):
        cur = measured()
        del cur["derived"]["dse_points_per_sec"]
        code, out = gate(cur, measured())
        self.assertEqual(code, 1)
        self.assertIn("missing from current", out)

    def test_missing_metric_in_baseline_warns_and_passes(self):
        # a newly added bench row predates the committed baseline — the
        # gate must not fail the PR that introduces the metric
        base = measured()
        del base["derived"]["serve_failover_reqs_per_sec"]
        code, out = gate(measured(), base)
        self.assertEqual(code, 0, out)
        self.assertIn("missing from baseline", out)
        self.assertIn("warning", out)

    def test_missing_baseline_metric_does_not_mask_other_regressions(self):
        base = measured()
        del base["derived"]["serve_failover_reqs_per_sec"]
        code, out = gate(measured(engine=1.4), base)
        self.assertEqual(code, 1)
        self.assertIn("engine_speedup_mha_batch64", out)

    def test_failover_throughput_regression_fails(self):
        code, out = gate(measured(failover=50000.0), measured())
        self.assertEqual(code, 1)
        self.assertIn("serve_failover_reqs_per_sec", out)
        self.assertIn("regression", out)

    def test_trace_overhead_growth_fails_lower_is_better(self):
        # traced/untraced host-time ratio: growth beyond tolerance means
        # the observability layer got more expensive on the hot path
        code, out = gate(measured(trace_overhead=2.0), measured(trace_overhead=1.2))
        self.assertEqual(code, 1)
        self.assertIn("serve_trace_overhead", out)
        self.assertIn("regression", out)

    def test_trace_overhead_within_tolerance_passes(self):
        code, out = gate(measured(trace_overhead=1.6), measured(trace_overhead=1.2))
        self.assertEqual(code, 0, out)  # 1.33x growth < 1.5x ceiling

    def test_trace_overhead_missing_from_baseline_warns_and_passes(self):
        # the PR that introduces the traced-serve bench row predates the
        # committed baseline — the gate must not fail it
        base = measured()
        del base["derived"]["serve_trace_overhead"]
        code, out = gate(measured(), base)
        self.assertEqual(code, 0, out)
        self.assertIn("serve_trace_overhead", out)
        self.assertIn("missing from baseline", out)

    def test_contention_pessimism_growth_fails_lower_is_better(self):
        # single-pass/fixed-point contended p50 ratio: growth beyond
        # tolerance means the conservative bound is drifting further from
        # the calibrated fixed point (over-throttling by more)
        code, out = gate(measured(pessimism=1.8), measured(pessimism=1.05))
        self.assertEqual(code, 1)
        self.assertIn("serve_contention_pessimism", out)
        self.assertIn("regression", out)

    def test_contention_pessimism_within_tolerance_passes(self):
        code, out = gate(measured(pessimism=1.4), measured(pessimism=1.05))
        self.assertEqual(code, 0, out)  # 1.33x growth < 1.5x ceiling

    def test_contention_pessimism_missing_from_baseline_warns_and_passes(self):
        # the PR that introduces the fixed-point bench row predates the
        # committed baseline — the gate must not fail it
        base = measured()
        del base["derived"]["serve_contention_pessimism"]
        code, out = gate(measured(), base)
        self.assertEqual(code, 0, out)
        self.assertIn("serve_contention_pessimism", out)
        self.assertIn("missing from baseline", out)

    def test_cluster_throughput_regression_fails(self):
        # cluster-era routing throughput is higher-is-better like the
        # other req/s metrics: a 0.33x drop breaches the 0.5x floor
        code, out = gate(measured(cluster=40000.0), measured())
        self.assertEqual(code, 1)
        self.assertIn("serve_cluster_reqs_per_sec", out)
        self.assertIn("regression", out)

    def test_cluster_throughput_within_tolerance_passes(self):
        code, out = gate(measured(cluster=70000.0), measured())
        self.assertEqual(code, 0, out)  # 0.58x >= 0.5x floor

    def test_cluster_throughput_missing_from_baseline_warns_and_passes(self):
        # the PR that introduces the cluster bench row predates the
        # committed baseline — the gate must not fail it
        base = measured()
        del base["derived"]["serve_cluster_reqs_per_sec"]
        code, out = gate(measured(), base)
        self.assertEqual(code, 0, out)
        self.assertIn("serve_cluster_reqs_per_sec", out)
        self.assertIn("missing from baseline", out)

    def test_router_scaling_growth_fails_lower_is_better(self):
        # indexed-route 64-backend / 2-backend per-request cost ratio:
        # growth beyond tolerance means per-arrival admission cost is
        # creeping back toward a linear rescan as the fleet widens
        code, out = gate(measured(router_scaling=7.0), measured(router_scaling=4.0))
        self.assertEqual(code, 1)
        self.assertIn("serve_router_scaling", out)
        self.assertIn("regression", out)

    def test_router_scaling_within_tolerance_passes(self):
        code, out = gate(measured(router_scaling=5.5), measured(router_scaling=4.0))
        self.assertEqual(code, 0, out)  # 1.375x growth < 1.5x ceiling

    def test_router_scaling_missing_from_baseline_warns_and_passes(self):
        # the PR that introduces the indexed-route bench rows predates
        # the committed baseline — the gate must not fail it
        base = measured()
        del base["derived"]["serve_router_scaling"]
        code, out = gate(measured(), base)
        self.assertEqual(code, 0, out)
        self.assertIn("serve_router_scaling", out)
        self.assertIn("missing from baseline", out)

    def test_mode_mismatch_warns_but_compares(self):
        code, out = gate(measured(smoke=True), measured(smoke=False))
        self.assertEqual(code, 0, out)
        self.assertIn("mode mismatch", out)

    def test_null_derived_reports_missing_metrics_instead_of_crashing(self):
        cur = measured()
        cur["derived"] = None
        code, out = gate(cur, measured())
        self.assertEqual(code, 1)
        self.assertIn("missing from current", out)
        # a baseline with no derived block at all warns per metric but
        # passes (the missing-from-baseline policy, degenerately)
        base = measured()
        base["derived"] = None
        code, out = gate(measured(), base)
        self.assertEqual(code, 0, out)
        self.assertIn("missing from baseline", out)

    def test_unreadable_file_exits_2_not_1(self):
        from bench_gate import main
        with self.assertRaises(SystemExit) as ctx:
            main(["--current", "/nonexistent/cur.json", "--baseline", "/nonexistent/base.json"])
        self.assertEqual(ctx.exception.code, 2)

    def test_non_numeric_metric_fails(self):
        cur = measured()
        cur["derived"]["engine_speedup_mha_batch64"] = "fast"
        code, out = gate(cur, measured())
        self.assertEqual(code, 1)
        self.assertIn("missing from current", out)


if __name__ == "__main__":
    unittest.main()
