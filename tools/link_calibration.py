#!/usr/bin/env python3
"""Calibration refresh tool for the dual link-contention bounds (stdlib only).

Independent re-implementation of ``rust/tests/link_calibration.rs``: the
serve layer reports two stretch bounds per partitioned member — the
conservative single-pass proportional bound and the optimistic clamped
fixed point (``--links-fixed-point``).  This tool replays the same
request/response-beat arbitration trace (weighted round-robin per
channel, beat bytes proportional to demand, a bounded completion window
coupling DRAM and PCIe, units released at the demand rate) and checks

    stretch_fixed_point  <=  reference  <=  stretch_single_pass

per member, within the beat-quantization tolerance.  Run it after any
change to ``rust/src/serve/links.rs`` (or to the scenarios below) to
confirm the bracket still holds, and use ``--json`` to dump the measured
reference stretches when refreshing the constants in the Rust test.

The arithmetic here is deliberately written from the model definitions,
not ported line-by-line from Rust — two independent implementations
agreeing is the point of a calibration harness.

Usage:
    python3 tools/link_calibration.py [--json] [--tolerance 0.03]

Exit code 0 = every scenario brackets, 1 = bracket violated, 2 = bad
invocation.
"""

import argparse
import json
import sys

UNITS = 400  # work units per member before the snapshot
BEATS = 16  # beats per unit per channel (beat bytes = demand / BEATS)
WINDOW = 4  # units a member may run ahead of its completed frontier
MAX_SWEEPS = 32  # fixed-point iteration cap (mirrors FIXED_POINT_MAX_SWEEPS)
EPS = 1e-9  # fixed-point convergence epsilon (mirrors FIXED_POINT_EPS)

# (name, (dram_pool, pcie_pool), [(dram_demand, pcie_demand), ...])
SCENARIOS = [
    ("cross-pool-coupled", (100.0, 4.0), [(40.0, 6.0), (80.0, 1.0)]),
    ("single-pool-only", (100.0, 1e6), [(80.0, 0.5), (40.0, 0.5)]),
    ("uncontended", (200.0, 32.0), [(40.0, 4.0), (50.0, 6.0)]),
]


def pool_share(demand, total, pool):
    """Single-pass proportional grant and stretch for one member's slice
    of one pool (mirrors ``links::pool_share``)."""
    if demand <= 0.0:
        return 0.0, 1.0
    if pool <= 0.0:
        return 0.0, float("inf")
    granted = pool * demand / total if total > pool else demand
    solo = min(demand, pool)
    return granted, max(solo / granted, 1.0)


def single_pass(pools, demands):
    """Per-member (overall, per-pool) single-pass stretches."""
    totals = [sum(d[c] for d in demands) for c in range(2)]
    out = []
    for d in demands:
        per = [pool_share(d[c], totals[c], pools[c])[1] for c in range(2)]
        out.append((max(per), per))
    return out


def fixed_point(pools, demands):
    """Clamped fixed-point overall stretches (mirrors
    ``links::negotiate_fixed_point``): contender j's appetite on pool p
    shrinks by min(1, s_j^p / S_j) — only the stretch *in excess* of
    what pool p itself imposes is credited back — and each member's
    overall stretch is clamped to never rise, which makes the sweep
    monotone non-increasing and convergent."""
    sp = single_pass(pools, demands)
    per_pool = [per for (_, per) in sp]
    overall = [s for (s, _) in sp]

    def offered(d, s_pool, s_all):
        if s_pool == float("inf") and s_all == float("inf"):
            return d
        return d * min(1.0, s_pool / s_all)

    for _ in range(MAX_SWEEPS):
        changed = False
        nxt = list(overall)
        for i, d in enumerate(demands):
            cand = 1.0
            for c in range(2):
                rel = d[c] + sum(
                    offered(dj[c], per_pool[j][c], overall[j])
                    for j, dj in enumerate(demands)
                    if j != i
                )
                cand = max(cand, pool_share(d[c], rel, pools[c])[1])
            cand = min(cand, overall[i])
            if overall[i] - cand > EPS:
                changed = True
            nxt[i] = cand
        overall = nxt
        if not changed:
            return overall
    raise AssertionError("fixed point failed to converge within MAX_SWEEPS")


def solo_rate(pools, d):
    """Units/ns a member achieves alone: each channel moves
    min(demand, pool) bytes per ns."""
    rates = [min(d[c], pools[c]) / d[c] for c in range(2) if d[c] > 0.0]
    return min(rates) if rates else float("inf")


def replay(pools, demands):
    """Beat-level arbitration replay; returns per-member achieved rates
    (units/ns) over the fully-contended interval."""
    n = len(demands)
    beat = [[d[c] / BEATS for c in range(2)] for d in demands]
    served = [[0, 0] for _ in range(n)]
    free_at = [0.0, 0.0]
    cursor = [0, 0]
    now = 0.0

    def units_done(m):
        fronts = [served[m][c] / BEATS for c in range(2) if beat[m][c] > 0.0]
        return min([float(UNITS)] + fronts)

    def eligible(m, c):
        if beat[m][c] <= 0.0 or served[m][c] >= UNITS * BEATS:
            return False
        if served[m][c] // BEATS > now:
            return False  # unit not yet released
        # a member's completed-unit frontier gates both channels (window)
        done = min(
            [UNITS] + [served[m][k] // BEATS for k in range(2) if beat[m][k] > 0.0]
        )
        return served[m][c] < (done + WINDOW) * BEATS

    for _ in range(10_000_000):
        if any(units_done(m) >= UNITS for m in range(n)):
            break
        progressed = False
        for c in range(2):
            if free_at[c] > now:
                continue
            pick = next(
                (
                    (cursor[c] + k) % n
                    for k in range(n)
                    if eligible((cursor[c] + k) % n, c)
                ),
                None,
            )
            if pick is not None:
                free_at[c] = now + beat[pick][c] / pools[c]
                served[pick][c] += 1
                cursor[c] = (pick + 1) % n
                progressed = True
        if not progressed:
            events = [t for t in free_at if t > now]
            for m in range(n):
                for c in range(2):
                    if beat[m][c] > 0.0 and served[m][c] < UNITS * BEATS:
                        release = float(served[m][c] // BEATS)
                        if release > now:
                            events.append(release)
            if not events:
                raise AssertionError("deadlocked replay: no event to advance to")
            now = min(events)
    else:
        raise AssertionError("arbitration replay failed to terminate")

    horizon = max([now] + free_at)
    return [units_done(m) / horizon for m in range(n)]


def calibrate(tolerance):
    """Returns (ok, results) over every scenario."""
    ok = True
    results = []
    for name, pools, demands in SCENARIOS:
        sp = [s for (s, _) in single_pass(pools, demands)]
        fp = fixed_point(pools, demands)
        rates = replay(pools, demands)
        members = []
        for m, d in enumerate(demands):
            ref = solo_rate(pools, d) / rates[m]
            lo_ok = fp[m] <= ref * (1.0 + tolerance)
            hi_ok = ref <= sp[m] * (1.0 + tolerance)
            ok = ok and lo_ok and hi_ok and ref >= 1.0 - tolerance
            members.append(
                {
                    "member": m,
                    "stretch_single_pass": sp[m],
                    "stretch_fixed_point": fp[m],
                    "reference": ref,
                    "bracketed": lo_ok and hi_ok,
                }
            )
        results.append({"scenario": name, "members": members})
    return ok, results


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="replay the beat-level arbitration reference and check "
        "the single-pass/fixed-point stretch bounds bracket it"
    )
    ap.add_argument("--json", action="store_true", help="emit machine-readable results")
    ap.add_argument(
        "--tolerance",
        type=float,
        default=0.03,
        help="relative beat-quantization tolerance on the bracket (default 0.03)",
    )
    args = ap.parse_args(argv)
    if args.tolerance < 0.0:
        print("link_calibration: --tolerance must be non-negative", file=sys.stderr)
        return 2

    ok, results = calibrate(args.tolerance)
    if args.json:
        print(json.dumps({"ok": ok, "scenarios": results}, indent=2))
    else:
        for sc in results:
            print(f"scenario {sc['scenario']}:")
            for mm in sc["members"]:
                mark = "ok" if mm["bracketed"] else "VIOLATED"
                print(
                    "  member {member}: fixed-point {stretch_fixed_point:.4f} "
                    "<= reference {reference:.4f} <= single-pass "
                    "{stretch_single_pass:.4f}  [{mark}]".format(mark=mark, **mm)
                )
        print(
            "link_calibration: bracket holds on every scenario"
            if ok
            else "link_calibration: BRACKET VIOLATED — the bounds no longer "
            "enclose the arbitration reference"
        )
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
