//! BERT-Base(Limited AIE): the paper's third accelerator — only 64 AIEs
//! allowed, forcing the serial parallel mode, which trades latency for
//! near-perfect per-core efficiency (~150 GOPS/AIE, 100% deployment and
//! effective-utilization rates).
//!
//! ```sh
//! cargo run --release --example limited_aie
//! ```

use cat::arch::ParallelMode;
use cat::config::{HardwareConfig, ModelConfig};
use cat::customize::{customize, CustomizeOptions};
use cat::metrics::summarize;
use cat::sched::run_edpu;

fn main() -> anyhow::Result<()> {
    let model = ModelConfig::bert_base();

    println!("sweeping the AIE budget (simulating different Versal parts):\n");
    println!(
        "{:>6} {:>14} {:>10} {:>12} {:>12} {:>10}",
        "AIEs", "mode", "ms/item", "TOPS", "GOPS/AIE", "GOPS/W"
    );
    for aies in [400usize, 256, 128, 64, 16] {
        let hw = HardwareConfig::vck5000_limited(aies);
        let plan = customize(&model, &hw, &CustomizeOptions::default())?;
        let r = run_edpu(&plan, 16)?;
        let s = summarize(&plan, &r);
        println!(
            "{:>6} {:>14} {:>10.3} {:>12.2} {:>12.1} {:>10.0}",
            aies,
            plan.mha.mode.to_string(),
            s.sys_latency_ms,
            s.sys_tops,
            s.sys_gops_per_aie,
            s.gops_per_w
        );
    }

    // the paper's configuration: 64 AIEs
    let hw = HardwareConfig::vck5000_limited(64);
    let plan = customize(&model, &hw, &CustomizeOptions::default())?;
    assert_eq!(plan.mha.mode, ParallelMode::Serial);
    assert_eq!(plan.cores_deployed(), 64);
    let r = run_edpu(&plan, 16)?;
    let s = summarize(&plan, &r);
    println!(
        "\n64-AIE accelerator: {:.3} ms/item, {:.2} TOPS, {:.0} GOPS/AIE",
        s.sys_latency_ms, s.sys_tops, s.sys_gops_per_aie
    );
    println!("paper Table VI:     0.398 ms,  9.60 TOPS,  150 GOPS/AIE");
    println!(
        "deployment rate {:.0}% / eff. utilization {:.0}% (paper: 100% / 100%)",
        plan.deployment_rate() * 100.0,
        s.avg_eff_util * 100.0
    );
    assert!((plan.deployment_rate() - 1.0).abs() < 1e-9);
    assert!(s.sys_gops_per_aie > 100.0);
    println!("\n\"our framework can reasonably plan the parallel mode under\n\
              different hardware resources to maximize the AIE performance\"");
    Ok(())
}
