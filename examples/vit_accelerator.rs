//! ViT-Base accelerator: sequence padding (197 -> 256), the MHA padding
//! tax, and the batch-size sweep of Figure 5 for the ViT accelerator.
//!
//! ```sh
//! cargo run --release --example vit_accelerator
//! ```

use cat::config::{HardwareConfig, ModelConfig};
use cat::customize::{customize, CustomizeOptions};
use cat::report::{fig5, BatchPoint};
use cat::sched::run_edpu;

fn main() -> anyhow::Result<()> {
    let model = ModelConfig::vit_base();
    let hw = HardwareConfig::vck5000();
    let plan = customize(&model, &hw, &CustomizeOptions::default())?;

    println!("ViT-Base: L = {} padded to {} (MMSZ_AIE = {})", model.seq_len,
             model.padded_seq_len(plan.mmsz), plan.mmsz);
    println!(
        "useful fraction of padded MHA work: {:.1}% — \"a part of the throughput\n\
         is occupied by the padded data\" (paper §V.D)\n",
        model.useful_fraction(plan.mmsz) * 100.0
    );

    let mut pts = Vec::new();
    for batch in [1usize, 2, 4, 8, 16, 32] {
        let r = run_edpu(&plan, batch)?;
        pts.push(BatchPoint {
            batch,
            mha_tops: r.mha.tops(),
            ffn_tops: r.ffn.tops(),
            sys_tops: r.tops(),
        });
    }
    println!("{}", fig5("ViT-Base on VCK5000", &pts));

    // the padding tax: compare against BERT (same padded shapes, no tax)
    let bert = customize(&ModelConfig::bert_base(), &hw, &CustomizeOptions::default())?;
    let rb = run_edpu(&bert, 16)?;
    let rv = run_edpu(&plan, 16)?;
    println!(
        "BERT-Base {:.1} TOPS vs ViT-Base {:.1} TOPS at batch 16 \
         (paper: 35.2 vs 30.3 — the gap is the padding tax)",
        rb.tops(),
        rv.tops()
    );
    assert!(rv.tops() < rb.tops());
    Ok(())
}
