//! END-TO-END VALIDATION DRIVER: the full three-layer stack on a real
//! workload, proving all layers compose (recorded in EXPERIMENTS.md).
//!
//!  1. verifies PJRT numerics: the Pallas-tiled (EDPU/AIE-MM-PU schedule)
//!     encoder == the fused encoder, and mha_stage ∘ ffn_stage == layer;
//!  2. serves a stream of batched requests through the HOST coordinator
//!     (rust batcher -> EDPU worker pool -> PJRT executable) over a real
//!     BERT-Base-shaped encoder with synthetic int8 weights;
//!  3. reports host latency/throughput and the simulated VCK5000 latency
//!     for the same batches.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_serve
//! ```
//! Flags: --requests N --batch B --layers L --workers W --full-model

use std::time::Duration;

use cat::config::{HardwareConfig, ModelConfig};
use cat::coordinator::{synthetic_request, Host, HostConfig};
use cat::customize::{customize, CustomizeOptions};
use cat::runtime::{EncoderWeights, Runtime};
use cat::util::cli;

fn main() -> anyhow::Result<()> {
    let args = cli::parse(
        std::env::args().skip(1),
        &["requests", "batch", "layers", "workers"],
    );
    let n_requests = args.opt_usize("requests", 24);
    let max_batch = args.opt_usize("batch", 8);
    let layers = args.opt_usize("layers", if args.flag("full-model") { 12 } else { 2 });
    let workers = args.opt_usize("workers", 2);

    let model = ModelConfig::bert_base();
    let hw = HardwareConfig::vck5000();
    let plan = customize(&model, &hw, &CustomizeOptions::default())?;

    // ---- phase 1: numerics (the decomposition proof) ----
    println!("[1/3] verifying EDPU decomposition numerics on PJRT ...");
    let mut rt = Runtime::open("artifacts")?;
    println!("      platform: {}", rt.platform());
    let req = synthetic_request(&model, plan.mmsz, 0, 2024);
    let w = EncoderWeights::synthetic(&model, 7);
    let (f_fused, q_fused, _s) =
        rt.encoder_layer("encoder_layer_fused", &req.x_q, req.x_scale, &w)?;
    let (f_pallas, q_pallas, _s2) =
        rt.encoder_layer("encoder_layer_pallas", &req.x_q, req.x_scale, &w)?;
    let max_diff = f_fused
        .as_f32()?
        .iter()
        .zip(f_pallas.as_f32()?)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    anyhow::ensure!(max_diff < 1e-4, "tiling changed numerics: {max_diff}");
    anyhow::ensure!(q_fused.as_i8()? == q_pallas.as_i8()?, "int8 outputs differ");
    println!("      pallas-tiled == fused: max |diff| = {max_diff:.2e}  OK");

    // ---- phase 2: serve batched requests ----
    println!(
        "[2/3] serving {n_requests} requests ({layers}-layer encoder, batch<= {max_batch}, {workers} workers) ..."
    );
    let mut cfg = HostConfig::new(model.clone());
    cfg.layers = layers;
    cfg.workers = workers;
    cfg.max_batch = max_batch;
    cfg.batch_timeout = Duration::from_millis(2);
    cfg.plan = Some(plan.clone());
    let mut host = Host::start(cfg)?;
    for i in 0..n_requests {
        host.submit(synthetic_request(&model, plan.mmsz, i as u64, 5000 + i as u64));
    }
    let (responses, stats) = host.drain()?;
    anyhow::ensure!(responses.len() == n_requests, "lost responses");
    for r in &responses {
        let out = r.output.as_f32()?;
        anyhow::ensure!(out.iter().all(|v| v.is_finite()), "non-finite output");
        anyhow::ensure!(out.len() == 256 * 768);
    }

    // ---- phase 3: report ----
    println!("[3/3] results:");
    println!("      completed    : {}", stats.completed);
    println!("      wall time    : {:.2?}", stats.wall);
    println!(
        "      throughput   : {:.2} req/s (host CPU executing the XLA encoder)",
        stats.throughput_rps()
    );
    println!("      mean batch   : {:.1}", stats.mean_batch());
    println!("      p50 / p99    : {:.2?} / {:.2?}", stats.percentile(0.5), stats.percentile(0.99));
    if let Some(sim) = responses.iter().find_map(|r| r.simulated_batch_ns) {
        println!(
            "      simulated VCK5000 latency for one batch x {layers} layers: {:.3} ms",
            sim / 1e6
        );
        println!(
            "      (paper: 0.118 ms/layer at peak => {:.3} ms for {layers} layers)",
            0.118 * layers as f64
        );
    }
    println!("\ne2e OK — L1 (Pallas kernels) -> L2 (JAX encoder) -> AOT HLO ->");
    println!("L3 (rust PJRT runtime + batching coordinator) all compose.");
    Ok(())
}
