//! The paper's §V.B design case, end to end: every number the paper
//! derives for the BERT-Base accelerator, recomputed and asserted.
//!
//! ```sh
//! cargo run --release --example bert_design_case
//! ```

use cat::arch::ParallelMode;
use cat::config::{HardwareConfig, ModelConfig};
use cat::customize::{
    customize, eq3_mmsz, eq4_plio_aie, eq7_p_atb, factor1_mha, factor2_mha_bytes,
    CustomizeOptions,
};
use cat::workload::{layer_workload, MmSite};

fn main() -> anyhow::Result<()> {
    let model = ModelConfig::bert_base();
    let hw = HardwareConfig::vck5000();
    println!("== paper §V.B design case: BERT-Base on VCK5000 ==\n");

    // --- load analysis ---
    let wl = layer_workload(&model, 64, true);
    println!("one EDPU iteration (MHA + FFN) requires:");
    for mm in &wl.mms {
        println!(
            "  {:?}: {} x {}x{}x{} MM",
            mm.site, mm.count, mm.m, mm.k, mm.n
        );
    }
    let qkv = wl.mms_at(MmSite::QkvLb).unwrap();
    let proj = wl.mms_at(MmSite::ProjLb).unwrap();
    assert_eq!(qkv.count + proj.count, 4, "paper: 4x 256x768x768");
    assert_eq!(wl.mms_at(MmSite::AtbPre).unwrap().count, 12);
    assert_eq!(wl.mms_at(MmSite::AtbPost).unwrap().count, 12);

    // --- Eq. 3 / Eq. 4 ---
    let mmsz = eq3_mmsz(&hw, 1);
    let plio = eq4_plio_aie(&hw, mmsz, 1);
    println!("\nEq.3: MMSZ_AIE = {mmsz}   (paper: 64)");
    println!("Eq.4: PLIO_AIE = {plio}   (paper: 4)");
    assert_eq!((mmsz, plio), (64, 4));

    // --- Eq. 7: P_ATB ---
    let p_atb = eq7_p_atb(&model, mmsz, plio).unwrap();
    println!(
        "Eq.7: P_ATB    = {p_atb}   (paper: 4 — QKV LB outputs 256x256, one head needs 256x64)"
    );
    assert_eq!(p_atb, 4);

    // --- Eq. 5: parallel mode ---
    let f1 = factor1_mha(&model, &hw, mmsz, plio);
    let f2 = factor2_mha_bytes(&model, mmsz, plio, p_atb);
    println!(
        "Eq.5: Factor1 = {f1:.2} (< PRG_MAX_Pipeline_Depth = {})",
        hw.prg_max_pipeline_depth
    );
    println!(
        "Eq.5: Factor2 = {:.4} MiB (< Total_Buffer = {:.1} MiB)   (paper: 7.5625 MiB)",
        f2 as f64 / (1024.0 * 1024.0),
        hw.onchip_sram_bytes as f64 / (1024.0 * 1024.0)
    );
    assert_eq!(f2, 7_929_856); // exactly 7.5625 MiB

    // --- full plan ---
    let plan = customize(&model, &hw, &CustomizeOptions::default())?;
    assert_eq!(plan.mha.mode, ParallelMode::FullyPipelined);
    println!("\n=> fully-pipelined parallelization mode (as the paper concludes)");
    println!(
        "=> {} AIEs deployed = 4 Large (256) + 4 ATB x (2 Small + 1 Standard) (96)",
        plan.cores_deployed()
    );
    assert_eq!(plan.cores_deployed(), 352);
    assert!((plan.deployment_rate() - 0.88).abs() < 1e-9);
    println!("=> AIE deployment rate 88% (paper Table V)");

    println!("\ndesign case checks ALL PASSED");
    Ok(())
}
