//! Deriving accelerators for models beyond the paper's two benchmarks —
//! the "customized accelerator family" claim: every model gets its own
//! plan, and the Eq. 5/6 decisions flip where they should.
//!
//! ```sh
//! cargo run --release --example custom_model
//! ```

use cat::arch::ParallelMode;
use cat::config::{HardwareConfig, ModelConfig};
use cat::customize::{customize, CustomizeOptions};
use cat::sched::run_edpu;

fn model(name: &str, heads: usize, e: usize, dff: usize, l: usize, layers: usize) -> ModelConfig {
    ModelConfig { name: name.into(), heads, embed_dim: e, dff, seq_len: l, layers, bits: 8 }
}

fn main() -> anyhow::Result<()> {
    let hw = HardwareConfig::vck5000();
    let zoo = vec![
        model("bert-tiny", 2, 128, 512, 128, 2),
        model("bert-small", 8, 512, 2048, 256, 4),
        ModelConfig::bert_base(),
        model("bert-large", 16, 1024, 4096, 384, 24),
        model("deit-small", 6, 384, 1536, 197, 12),
        model("gpt2-medium-ctx1k", 16, 1024, 4096, 1024, 24),
        model("long-seq-4k", 12, 768, 3072, 4096, 12),
    ];

    println!(
        "{:<20} {:>5} {:>6} {:>6} {:>6} {:>16} {:>6} {:>9} {:>10}",
        "model", "MMSZ", "PLIO", "P_ATB", "AIEs", "MHA mode", "dep%", "TOPS", "ms/item"
    );
    for m in zoo {
        let plan = customize(&m, &hw, &CustomizeOptions::default())?;
        let r = run_edpu(&plan, 8)?;
        println!(
            "{:<20} {:>5} {:>6} {:>6} {:>6} {:>16} {:>5.0}% {:>9.2} {:>10.3}",
            m.name,
            plan.mmsz,
            plan.plio_aie,
            plan.p_atb,
            plan.cores_deployed(),
            plan.mha.mode.to_string(),
            plan.deployment_rate() * 100.0,
            r.tops(),
            r.latency_per_item_ns() / 1e6,
        );
        // the family property: every plan is feasible on the board
        assert!(plan.cores_deployed() <= hw.total_aie);
    }

    // long sequences blow the on-chip attention cache -> Eq. 5 must flip
    // the MHA stage out of fully-pipelined mode.
    let long = model("long-seq-4k", 12, 768, 3072, 4096, 12);
    let plan = customize(&long, &hw, &CustomizeOptions::default())?;
    assert_ne!(plan.mha.mode, ParallelMode::FullyPipelined);
    println!(
        "\nlong-seq-4k: Factor2 = {:.1} MiB > {:.1} MiB on-chip => {} (Eq. 5 flips the mode)",
        plan.factor2_mha_bytes as f64 / (1024.0 * 1024.0),
        hw.onchip_sram_bytes as f64 / (1024.0 * 1024.0),
        plan.mha.mode
    );
    Ok(())
}
