//! Quickstart: derive a customized accelerator for BERT-Base on a
//! VCK5000 and simulate one EDPU execution.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use cat::config::{HardwareConfig, ModelConfig};
use cat::customize::{customize, CustomizeOptions};
use cat::metrics::summarize;
use cat::sched::run_edpu;

fn main() -> anyhow::Result<()> {
    // 1. The two inputs to the CAT framework: a Transformer configuration
    //    and a Versal ACAP board description.
    let model = ModelConfig::bert_base();
    let hw = HardwareConfig::vck5000();

    // 2. Customize: Eq. 3-8 decide the three customizable attributes and
    //    allocate AIE MM PUs to PRGs.
    let plan = customize(&model, &hw, &CustomizeOptions::default())?;
    println!("derived accelerator for {} on {}:", model.name, hw.name);
    println!("  MMSZ_AIE = {}, PLIO_AIE = {}", plan.mmsz, plan.plio_aie);
    println!("  MHA mode {}, FFN mode {}", plan.mha.mode, plan.ffn.mode);
    println!("  P_ATB = {}", plan.p_atb);
    println!(
        "  {} / {} AIEs deployed ({:.0}%)",
        plan.cores_deployed(),
        hw.total_aie,
        plan.deployment_rate() * 100.0
    );

    // 3. Simulate an EDPU execution at batch 16 (near peak, Fig. 5).
    let report = run_edpu(&plan, 16)?;
    let s = summarize(&plan, &report);
    println!("\nsimulated performance (batch 16):");
    println!("  MHA    : {:.3} ms/item, {:.1} TOPS", s.mha_latency_ms, s.mha_tops);
    println!("  FFN    : {:.3} ms/item, {:.1} TOPS", s.ffn_latency_ms, s.ffn_tops);
    println!(
        "  System : {:.3} ms/item, {:.1} TOPS, {:.1} W, {:.0} GOPS/W",
        s.sys_latency_ms, s.sys_tops, s.power_w, s.gops_per_w
    );
    println!(
        "  AIE eff. utilization: MHA {:.0}%, FFN {:.0}%, avg {:.0}%",
        s.mha_eff_util * 100.0,
        s.ffn_eff_util * 100.0,
        s.avg_eff_util * 100.0
    );
    println!("\n(paper Table VI: 0.118 ms, 35.2 TOPS, 67.6 W, 521 GOPS/W)");
    Ok(())
}
